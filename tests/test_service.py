"""Service-tier tests: catalog, admission tiers, FIFO scheduling and
fused batch execution.

The acceptance bar (ISSUE 4): K >= 4 same-algorithm BFS/SSSP tickets
submitted to the service execute as ONE fused pregel program — exactly
one ``run_pregel`` invocation, visible both in ``QueryResult.meta`` and
by counting actual calls — and every ticket's result is bit-identical
to running its query alone through ``GraphPlatform.query``.
"""
import dataclasses
from collections import OrderedDict

import numpy as np
import pytest

from repro.core import graph as G
from repro.core import planner as P
from repro.core.algorithms import traversal  # noqa: F401 (registration)
from repro.core.engines import Engine
from repro.core.query import GraphPlatform, GraphQuery
from repro.core.service import (AdmissionRejected, GraphAnalyticsService,
                                QueryTicket)
from repro.data import synthetic as S

N = 260


def _bits(x):
    return np.asarray(x).tobytes()


@pytest.fixture(scope="module")
def graph():
    src, dst = S.user_follow_graph(N, 4.0, seed=11)
    return G.build_coo(src, dst, N)


@pytest.fixture(scope="module")
def sym_graph():
    src, dst = S.user_follow_graph(N, 4.0, seed=11)
    keep = src != dst
    return G.build_coo(src[keep], dst[keep], N, symmetrize=True)


def _batch_service(graph, **add_kw):
    """A service where every admitted ticket lands in the batch tier."""
    svc = GraphAnalyticsService(interactive_threshold_s=0.0)
    svc.add_graph("g", graph, **add_kw)
    return svc


@pytest.fixture()
def count_pregel_calls(monkeypatch):
    """Count fused pregel executions: the traversal batch runner
    dispatches each fused program through Engine.run_superstep exactly
    once (whatever superstep strategy — dense, fused kernel, frontier —
    the engine then resolves)."""
    calls = {"n": 0}
    real = Engine.run_superstep

    def counting(self, *a, **kw):
        calls["n"] += 1
        return real(self, *a, **kw)

    monkeypatch.setattr(Engine, "run_superstep", counting)
    return calls


# ------------------------------------------------------------ fused batches

@pytest.mark.parametrize("force_engine", ["local", "distributed"])
def test_fused_bfs_acceptance(graph, force_engine, count_pregel_calls):
    """K=5 BFS tickets -> one pregel invocation, results bit-identical
    to solo GraphPlatform.query runs, on both engines."""
    svc = _batch_service(graph, n_data=4, force_engine=force_engine)
    sources = [(0,), (5,), (9,), (17,), (42,)]
    tickets = [svc.submit("g", GraphQuery.bfs(s)) for s in sources]
    assert all(t.tier == "batch" for t in tickets)

    count_pregel_calls["n"] = 0
    svc.drain()
    assert count_pregel_calls["n"] == 1          # ONE fused execution

    solo = GraphPlatform(graph, n_data=4, force_engine=force_engine)
    for t in tickets:
        r = svc.result(t)
        assert r.engine == force_engine
        fused = r.meta["fused"]
        assert fused["batch_size"] == len(sources)
        assert fused["pregel_calls"] == 1
        assert _bits(r.value) == _bits(solo.query(t.query).value)
    assert svc.stats["fused_batches"] == 1
    assert svc.stats["fused_tickets"] == len(sources)


@pytest.mark.parametrize("force_engine", ["local", "distributed"])
def test_fused_sssp_parity(graph, force_engine):
    svc = _batch_service(graph, n_data=4, force_engine=force_engine)
    tickets = [svc.submit("g", GraphQuery.sssp(s)) for s in (0, 3, 7, 31)]
    svc.drain()
    solo = GraphPlatform(graph, n_data=4, force_engine=force_engine)
    for t in tickets:
        r = svc.result(t)
        assert r.meta["fused"]["batch_size"] == 4
        assert _bits(r.value) == _bits(solo.query(t.query).value)


def test_fused_jaccard_parity(graph):
    svc = _batch_service(graph)
    queries = [GraphQuery.of("jaccard", u=[0, 1], v=[2, 3]),
               GraphQuery.of("jaccard", u=[5], v=[9]),
               GraphQuery.of("jaccard", u=[10, 11, 12], v=[13, 14, 15])]
    tickets = [svc.submit("g", q) for q in queries]
    svc.drain()
    solo = GraphPlatform(graph)
    for t in tickets:
        r = svc.result(t)
        assert r.meta["fused"]["batch_size"] == 3
        assert r.meta["fused"]["kernel_calls"] == 1
        assert _bits(r.value) == _bits(solo.query(t.query).value)


def test_fused_count_only_applies_reducer(graph):
    """count_only is per-ticket within a fused batch: the reducer runs
    on that ticket's slice of the shared execution."""
    svc = _batch_service(graph)
    t_full = svc.submit("g", GraphQuery.bfs([0]))
    t_count = svc.submit("g", GraphQuery.bfs([3], count_only=True))
    svc.drain()
    assert svc.result(t_full).meta["fused"]["batch_size"] == 2
    solo = GraphPlatform(graph)
    assert svc.result(t_count).value == \
        solo.query(GraphQuery.bfs([3], count_only=True)).value


def test_fuse_key_separates_incompatible_queries(graph, count_pregel_calls):
    """Differing max_iters must not fuse (the loop bound is shared);
    the queue splits into two fused groups."""
    svc = _batch_service(graph)
    a = [svc.submit("g", GraphQuery.bfs([s])) for s in (0, 1)]
    b = [svc.submit("g", GraphQuery.bfs([s], max_iters=2)) for s in (2, 3)]
    count_pregel_calls["n"] = 0
    svc.drain()
    assert count_pregel_calls["n"] == 2
    assert svc.result(a[0]).meta["fused"]["batch_size"] == 2
    assert svc.result(b[0]).meta["fused"]["batch_size"] == 2
    assert svc.stats["fused_batches"] == 2


def test_fusion_never_crosses_graphs(graph, sym_graph):
    """Same algorithm, same fuse key, different snapshots: two separate
    executions (a fused program shares one graph's edge shards)."""
    svc = GraphAnalyticsService(interactive_threshold_s=0.0)
    svc.add_graph("a", graph)
    svc.add_graph("b", sym_graph)
    ta = [svc.submit("a", GraphQuery.bfs([s])) for s in (0, 1)]
    tb = [svc.submit("b", GraphQuery.bfs([s])) for s in (0, 1)]
    svc.drain()
    assert svc.result(ta[0]).meta["fused"]["batch_size"] == 2
    assert svc.result(tb[0]).meta["fused"]["batch_size"] == 2
    assert svc.stats["fused_batches"] == 2
    solo_b = GraphPlatform(sym_graph)
    assert _bits(svc.result(tb[1]).value) == \
        _bits(solo_b.query(tb[1].query).value)


# ------------------------------------------------------- FIFO determinism

def _run_mixed(graph):
    svc = GraphAnalyticsService(interactive_threshold_s=0.0)
    svc.add_graph("g", graph, n_data=4)
    svc.submit("g", GraphQuery.bfs([0]))                    # 0 fuses w/ 3
    svc.submit("g", GraphQuery.pagerank(max_iters=5))       # 1 solo
    svc.submit("g", GraphQuery.of("jaccard", u=[0], v=[1]))  # 2 solo-batch
    svc.submit("g", GraphQuery.bfs([7]))                    # 3
    svc.submit("g", GraphQuery.sssp(2))                     # 4 fuses w/ 5
    svc.submit("g", GraphQuery.sssp(9))                     # 5
    svc.drain()
    return [(e["algorithm"], tuple(e["tickets"]), e["fused"])
            for e in svc.execution_log]


def test_fifo_deterministic_and_fuses_across_queue(graph):
    """Two identical submission sequences produce identical execution
    logs; fusion pulls compatible tickets forward to their group head
    but group heads stay in FIFO order."""
    log1, log2 = _run_mixed(graph), _run_mixed(graph)
    assert log1 == log2
    heads = [t[1][0] for t in log1]
    assert heads == sorted(heads)            # group heads in FIFO order
    by_algo = {t[0]: t for t in log1}
    assert by_algo["bfs"] == ("bfs", (0, 3), True)
    assert by_algo["sssp"] == ("sssp", (4, 5), True)
    assert by_algo["pagerank"][2] is False


# ------------------------------------------------- tiers, bypass, admission

def test_interactive_bypasses_batch_queue(graph):
    """An interactive ticket resolves immediately even with older batch
    work queued ahead of it — and the batch work stays queued.  The
    tier split is produced the way production would: a calibration
    profile whose measured pagerank constant pushes it over the
    interactive threshold."""
    try:
        P.set_calibration(P.CalibrationProfile(
            algo_time_scale={"pagerank": 1e9}))
        svc = GraphAnalyticsService(interactive_threshold_s=1e-2)
        svc.add_graph("g", graph)
        batch_t = svc.submit("g", GraphQuery.pagerank(max_iters=5))
        assert batch_t.tier == "batch"
        quick = svc.submit("g", GraphQuery.degree_stats())
        assert quick.tier == "interactive"
        r = svc.result(quick)
        assert r.value is not None
        assert batch_t.status == "queued"        # bypassed, not drained
        assert [t.ticket_id for t in svc.pending()] == [batch_t.ticket_id]
        svc.drain()
        assert batch_t.status == "done"
    finally:
        P.set_calibration(None)


def test_tier_classification_follows_threshold(graph):
    lo = GraphAnalyticsService(interactive_threshold_s=0.0)
    hi = GraphAnalyticsService(interactive_threshold_s=1e9)
    lo.add_graph("g", graph)
    hi.add_graph("g", graph)
    q = GraphQuery.degree_stats()
    assert lo.submit("g", q).tier == "batch"
    assert hi.submit("g", q).tier == "interactive"


def test_admission_rejection_carries_plan(graph):
    svc = GraphAnalyticsService(admission_budget_s=1e-12)
    svc.add_graph("g", graph)
    with pytest.raises(AdmissionRejected) as exc:
        svc.submit("g", GraphQuery.pagerank())
    e = exc.value
    assert isinstance(e.plan, P.Plan)
    assert e.plan.engine in ("local", "distributed")
    assert e.est_s == P.plan_cost(e.plan)
    assert e.budget_s == 1e-12
    assert e.query.algorithm == "pagerank"
    assert svc.stats["rejected"] == 1
    assert svc.stats["submitted"] == 0
    assert not svc.pending()


def test_thresholds_follow_active_calibration_profile(graph, tmp_path):
    """Unpinned services read tier thresholds from the active
    calibration profile — load_calibration retunes them live."""
    svc = GraphAnalyticsService()
    svc.add_graph("g", graph)
    path = tmp_path / "profile.json"
    try:
        P.CalibrationProfile(interactive_threshold_s=123.0,
                             admission_budget_s=456.0).to_json(path)
        P.load_calibration(path)
        assert svc.interactive_threshold_s == 123.0
        assert svc.admission_budget_s == 456.0
    finally:
        P.set_calibration(None)
    assert svc.interactive_threshold_s == \
        P.CalibrationProfile().interactive_threshold_s


# ------------------------------------------------ catalog + result cache

def test_catalog_digest_dedup_shares_context(graph):
    svc = GraphAnalyticsService()
    svc.add_graph("a", graph)
    reload_ = G.GraphCOO(graph.src, graph.dst, graph.w, graph.n_vertices,
                         graph.n_edges, graph.symmetric)
    svc.add_graph("b", reload_)
    assert svc.context("a") is svc.context("b")   # one set of engines
    # same bytes but different engine config -> distinct context
    svc.add_graph("c", graph, n_data=4)
    assert svc.context("c") is not svc.context("a")


def test_shared_result_cache_across_snapshot_names(graph):
    """A query answered under one catalog name is a hit under every
    name bound to byte-identical bytes — even a distinct context."""
    svc = GraphAnalyticsService()
    svc.add_graph("a", graph)
    svc.add_graph("c", graph, n_data=4)           # distinct context
    q = GraphQuery.connected_components(count_only=True) \
        if graph.symmetric else GraphQuery.pagerank(max_iters=10)
    r1 = svc.call("a", q)
    assert svc.cache_stats == {"hits": 0, "misses": 1}
    r2 = svc.call("c", q)
    assert r2.meta.get("cache") == "hit"
    assert _bits(r2.value) == _bits(r1.value)
    assert svc.context("c")._local is None        # served without engines


def test_fused_batch_results_enter_shared_cache(graph):
    """A ticket answered by a fused batch seeds the result cache: the
    same query re-submitted (or re-called synchronously) is a hit."""
    svc = _batch_service(graph)
    tickets = [svc.submit("g", GraphQuery.bfs([s])) for s in (0, 5, 9, 17)]
    svc.drain()
    runs_before = svc.context("g").local.n_runs
    r = svc.call("g", GraphQuery.bfs([5]))
    assert r.meta.get("cache") == "hit"
    assert _bits(r.value) == _bits(svc.result(tickets[1]).value)
    t_again = svc.submit("g", GraphQuery.bfs([9]))
    svc.drain()
    assert svc.result(t_again).meta.get("cache") == "hit"
    assert svc.context("g").local.n_runs == runs_before


def test_ticket_result_is_reusable(graph):
    svc = _batch_service(graph)
    t = svc.submit("g", GraphQuery.bfs([0]))
    r1 = svc.result(t)                 # drains
    r2 = svc.result(t)                 # already done: stored result
    assert r1 is r2
    assert isinstance(t, QueryTicket) and t.status == "done"


def test_unknown_graph_name_raises(graph):
    svc = GraphAnalyticsService()
    with pytest.raises(KeyError, match="catalog"):
        svc.submit("nope", GraphQuery.degree_stats())


# ------------------------------------------------- measured-stats feedback

def test_measured_oriented_width_reaches_triangle_cost(sym_graph):
    """Satellite: once an engine has built the OrientedELL, its measured
    row width replaces the analytic d_max estimate in the triangle cost
    hook, and the plan cache is invalidated to re-cost."""
    plat = GraphPlatform(sym_graph)
    assert plat.stats.oriented_width is None
    analytic = {s.variant: s for s in P.specs_for("triangle_count",
                                                  plat.stats)}
    plat.local.run("triangle_count", variant="intersect")
    width = plat.local.oriented.max_out_degree
    stats = plat.stats
    assert stats.oriented_width == width
    measured = {s.variant: s for s in P.specs_for("triangle_count", stats)}
    assert measured["intersect"].state_bytes_per_vertex == 4.0 * width
    assert measured["intersect"].state_bytes_per_vertex != \
        analytic["intersect"].state_bytes_per_vertex
    # the re-cost actually flows into a fresh plan (cache invalidated)
    plan = plat.plan(GraphQuery.triangle_count())
    assert plan.variant in ("bitset", "intersect")


def test_max_degree_measured_from_ell_build(graph):
    plat = GraphPlatform(graph)
    _ = plat.local.ell
    dst = np.asarray(graph.dst)[: graph.n_edges]
    want = int(np.bincount(dst, minlength=graph.n_vertices).max())
    assert plat.stats.max_degree == want


def test_with_measurements_rejects_unknown_fields():
    s = P.GraphStats(10, 20, 240)
    with pytest.raises(ValueError, match="unknown measurement"):
        s.with_measurements({"bogus": 1})
    assert dataclasses.replace(s) == s.with_measurements({})


# ------------------------------------------------- engine-free cache key

def test_result_cache_key_is_engine_free(graph):
    """Regression (satellite): re-planning the same query onto the other
    engine (force_engine toggled / chip count changed) must be a cache
    hit — results are contractually engine-independent."""
    shared = OrderedDict()
    p_local = GraphPlatform(graph, result_cache=shared)
    q = GraphQuery.pagerank(max_iters=8)
    first = p_local.query(q)
    assert first.engine == "local"
    p_forced = GraphPlatform(graph, n_data=4, force_engine="distributed",
                             result_cache=shared)
    r = p_forced.query(q)
    assert r.meta.get("cache") == "hit"
    assert _bits(r.value) == _bits(first.value)
    assert p_forced._dist is None          # never built an engine


# ------------------------------------------------- review-fix regressions

def test_calibration_change_invalidates_cached_plans(graph):
    """A profile swap must re-cost cached plans — a live service/platform
    retunes instead of serving stale pre-calibration estimates."""
    plat = GraphPlatform(graph)
    q = GraphQuery.pagerank(max_iters=5)
    p1 = plat.plan(q)
    try:
        P.set_calibration(P.CalibrationProfile(
            algo_time_scale={"pagerank": 1e6}))
        p2 = plat.plan(q)
        assert p2.est_local_s == pytest.approx(p1.est_local_s * 1e6)
    finally:
        P.set_calibration(None)
    assert plat.plan(q).est_local_s == pytest.approx(p1.est_local_s)


def test_stale_plan_cannot_dodge_admission_after_recalibration(graph):
    svc = GraphAnalyticsService(interactive_threshold_s=0.0)
    svc.add_graph("g", graph)
    svc.submit("g", GraphQuery.bfs([0]))     # caches the cheap plan
    try:
        P.set_calibration(P.CalibrationProfile(
            algo_time_scale={"bfs": 1e12}, admission_budget_s=1.0))
        with pytest.raises(AdmissionRejected):
            svc.submit("g", GraphQuery.bfs([0]))
    finally:
        P.set_calibration(None)
    svc.drain()


def test_directly_constructed_query_fuses_safely(graph):
    """A GraphQuery built without schema defaults filled (bypassing
    ``.of``) must not crash the drain: fuse keys are computed over
    validated params."""
    svc = _batch_service(graph)
    t_raw = svc.submit("g", GraphQuery("bfs", params={"sources": (0,)}))
    t_of = svc.submit("g", GraphQuery.bfs([1]))
    svc.drain()
    assert svc.result(t_raw).meta["fused"]["batch_size"] == 2
    solo = GraphPlatform(graph)
    assert _bits(svc.result(t_raw).value) == \
        _bits(solo.query(GraphQuery.bfs([0])).value)
    assert _bits(svc.result(t_of).value) == \
        _bits(solo.query(GraphQuery.bfs([1])).value)


def test_plan_cache_disabled_with_cache_size_zero(graph):
    plat = GraphPlatform(graph, cache_size=0)
    q = GraphQuery.pagerank()
    assert plat.plan(q) is not plat.plan(q)      # nothing cached


def test_foreign_ticket_rejected(graph):
    svc_a = _batch_service(graph)
    svc_b = _batch_service(graph)
    t = svc_a.submit("g", GraphQuery.bfs([0]))
    svc_b.submit("g", GraphQuery.degree_stats())
    with pytest.raises(ValueError, match="not issued by this service"):
        svc_b.result(t)


def test_remove_graph_releases_context(graph):
    svc = GraphAnalyticsService()
    svc.add_graph("a", graph)
    svc.add_graph("b", graph)
    ctx = svc.context("a")
    svc.remove_graph("a")
    assert "a" not in svc.graph_names()
    assert svc.context("b") is ctx               # still referenced by 'b'
    assert svc._by_digest
    svc.remove_graph("b")
    assert not svc._by_digest                    # context fully released
    with pytest.raises(KeyError):
        svc.context("b")
    svc.remove_graph("never-added")              # no-op, no raise


def test_pending_tickets_survive_remove_and_rebind(graph, sym_graph):
    """Tickets pin their context at submit: removing the catalog name —
    or rebinding it to a different snapshot — must not redirect or
    strand queued work."""
    svc = _batch_service(graph)
    t = svc.submit("g", GraphQuery.bfs([0]))
    svc.remove_graph("g")
    svc.drain()                                  # executes fine
    solo = GraphPlatform(graph)
    assert _bits(svc.result(t).value) == \
        _bits(solo.query(GraphQuery.bfs([0])).value)

    # rebinding: the queued ticket still runs on the ORIGINAL snapshot
    svc2 = _batch_service(graph)
    t2 = svc2.submit("g", GraphQuery.bfs([0], count_only=True))
    svc2.add_graph("g", sym_graph)               # name now means new bytes
    svc2.drain()
    assert svc2.result(t2).value == \
        solo.query(GraphQuery.bfs([0], count_only=True)).value
    # and new submissions target the rebound snapshot
    t3 = svc2.submit("g", GraphQuery.bfs([0], count_only=True))
    svc2.drain()
    assert svc2.result(t3).value == GraphPlatform(sym_graph).query(
        GraphQuery.bfs([0], count_only=True)).value


def test_failing_execution_fails_ticket_not_drain(graph):
    """An execution error must not strand its ticket or abort the rest
    of the queue: the ticket dead-letters (a schema ValueError is
    permanent — no retries burned), result() re-raises, and every
    other ticket still completes."""
    svc = _batch_service(graph)
    # missing required param: planning tolerates it (partial validate),
    # execution raises in the engine's schema check
    bad = svc.submit("g", GraphQuery("bfs", params={}))
    good = svc.submit("g", GraphQuery.bfs([1]))
    finished = svc.drain()
    assert {t.ticket_id for t in finished} == {bad.ticket_id,
                                               good.ticket_id}
    assert bad.status == "dead-letter" and good.status == "done"
    assert bad.attempts == 1                 # permanent error: no retry
    assert svc.stats["failed"] == 1
    assert svc.stats["dead_letters"] == 1
    assert not svc.pending()
    with pytest.raises(ValueError, match="missing required parameter"):
        svc.result(bad)
    solo = GraphPlatform(graph)
    assert _bits(svc.result(good).value) == \
        _bits(solo.query(GraphQuery.bfs([1])).value)


def test_infeasible_plan_rejected_even_under_infinite_budget(graph):
    """plan_cost == inf (planner declared the clamped engine
    memory-infeasible) must reject: `inf > inf` is False, so the budget
    comparison alone would admit a guaranteed OOM."""
    try:
        P.set_calibration(P.CalibrationProfile(local_mem_budget=0.0))
        svc = GraphAnalyticsService()            # default budget: inf
        svc.add_graph("g", graph)
        with pytest.raises(AdmissionRejected) as exc:
            # jaccard is local-only: the capability clamp forces the
            # engine whose estimate just went infinite
            svc.submit("g", GraphQuery.of("jaccard", u=[0], v=[1]))
        assert exc.value.est_s == float("inf")
    finally:
        P.set_calibration(None)


def test_cache_hit_does_not_replay_fused_meta(graph):
    """'fused' describes one execution; a later cache hit for the same
    query must not claim it was part of that batch."""
    svc = _batch_service(graph)
    tickets = [svc.submit("g", GraphQuery.bfs([s])) for s in (0, 5)]
    svc.drain()
    assert svc.result(tickets[0]).meta["fused"]["batch_size"] == 2
    hit = svc.call("g", GraphQuery.bfs([0]))
    assert hit.meta.get("cache") == "hit"
    assert "fused" not in hit.meta


def test_resolved_ticket_history_is_bounded(graph):
    """A long-lived service must not accrete tickets + O(V) results
    forever: resolved entries age out beyond history_size (pending ones
    never do), and an aged-out ticket fails resolution loudly."""
    svc = GraphAnalyticsService(interactive_threshold_s=0.0,
                                cache_size=0, history_size=2)
    svc.add_graph("g", graph)
    ts = [svc.submit("g", GraphQuery.bfs([s], count_only=True))
          for s in (0, 1, 2)]
    svc.drain()
    assert len(svc._tickets) == 2 and len(svc._results) == 2
    with pytest.raises(ValueError, match="aged out"):
        svc.result(ts[0])                        # oldest: evicted
    assert svc.result(ts[2]).value is not None   # newest: retained


def test_direct_engine_variant_selection_uses_measurements(sym_graph):
    """Satellite follow-through: an engine called without a plan resolves
    its variant from *measured* structure, not the analytic stand-in."""
    from repro.core.engines import LocalEngine
    narrow = LocalEngine(sym_graph)
    narrow._measured["oriented_width"] = 1       # intersect nearly free
    assert narrow.run("triangle_count").meta["variant"] == "intersect"
    wide = LocalEngine(sym_graph)
    wide._measured["oriented_width"] = 10**6     # intersect astronomical
    assert wide.run("triangle_count").meta["variant"] == "bitset"


# ------------------------------------------------- batched_spec contract

def test_batched_spec_rejects_structured_messages():
    from repro.core.pregel import PregelSpec, batched_spec
    structured = PregelSpec(
        message=lambda s, w: s, combine=(("sum", 1), ("min", 1)),
        apply=lambda old, agg, ids, gval: agg, identity=(0.0, 0.0))
    with pytest.raises(ValueError, match="batch axis"):
        batched_spec(structured)


def test_batched_spec_memoized():
    from repro.core.pregel import batched_spec
    spec = traversal._BFS_SPEC
    assert batched_spec(spec) is batched_spec(spec)
