"""HITS: the registry's one-file-extension example.

Checks the algorithm itself (oracle + known graphs) and the extension
contract: the definition reached the planner, both engines and the
query layer purely through registration (this module's sibling,
``algorithms/hits.py``, touches none of them).
"""
import numpy as np
import pytest

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R
from repro.core.algorithms.hits import hits, hits_reference, role_graph
from repro.core.engines import DistributedEngine, LocalEngine
from repro.core.query import GraphPlatform, GraphQuery
from repro.data import synthetic as S


def _graph(n=250, seed=3):
    src, dst = S.user_follow_graph(n, 4.0, seed=seed)
    return G.build_coo(src, dst, n), src, dst


def test_hits_matches_numpy_oracle():
    g, src, dst = _graph()
    got, _ = hits(g)
    want, _ = hits_reference(src, dst, g.n_vertices)
    # same schedule, float32 device vs float64 host
    np.testing.assert_allclose(np.asarray(got["hubs"]), want["hubs"],
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(got["authorities"]),
                               want["authorities"], atol=1e-4)


def test_hits_against_networkx():
    networkx = pytest.importorskip("networkx")
    g, src, dst = _graph(n=120, seed=9)
    got, _ = hits(g, max_iters=200, tol=1e-10)
    gg = networkx.DiGraph()
    gg.add_nodes_from(range(g.n_vertices))
    gg.add_edges_from(zip(src.tolist(), dst.tolist()))
    h_ref, a_ref = networkx.hits(gg, max_iter=500, tol=1e-12)
    h_ref = np.array([h_ref[i] for i in range(g.n_vertices)])
    a_ref = np.array([a_ref[i] for i in range(g.n_vertices)])
    # networkx L1-normalizes; compare directions
    def l1(x):
        x = np.abs(np.asarray(x, np.float64))
        return x / max(x.sum(), 1e-12)
    np.testing.assert_allclose(l1(got["hubs"]), l1(h_ref), atol=1e-4)
    np.testing.assert_allclose(l1(got["authorities"]), l1(a_ref), atol=1e-4)


def test_hits_star_graph():
    """Edges all point at vertex 0: it is the sole authority, and every
    spoke is an equal hub."""
    n = 6
    src = np.arange(1, n)
    dst = np.zeros(n - 1, dtype=np.int64)
    g = G.build_coo(src, dst, n)
    got, _ = hits(g)
    auth = np.asarray(got["authorities"])
    hubs = np.asarray(got["hubs"])
    assert auth[0] == pytest.approx(1.0)
    np.testing.assert_allclose(auth[1:], 0.0, atol=1e-7)
    assert hubs[0] == pytest.approx(0.0, abs=1e-7)
    np.testing.assert_allclose(hubs[1:], hubs[1], atol=1e-6)


def test_hits_empty_graph_is_finite():
    g = G.build_coo(np.array([], np.int64), np.array([], np.int64), 4)
    got, _ = hits(g, max_iters=4)
    assert np.isfinite(np.asarray(got["hubs"])).all()
    assert np.isfinite(np.asarray(got["authorities"])).all()


def test_role_graph_shape():
    g, src, dst = _graph(n=50, seed=1)
    rg = role_graph(g)
    assert rg.n_vertices == 2 * g.n_vertices
    assert rg.n_edges == 2 * g.n_edges


# ----------------------------------------------- extension contract

def test_hits_registered_via_discovery():
    assert "hits" in R.names()
    defn = R.get("hits")
    assert defn.engines == ("local", "distributed")


def test_hits_engine_parity_and_cached_shards():
    g, _, _ = _graph()
    lo, di = LocalEngine(g), DistributedEngine(g, n_data=4)
    r_lo, r_di = lo.hits(), di.hits()
    np.testing.assert_allclose(np.asarray(r_lo.value["hubs"]),
                               np.asarray(r_di.value["hubs"]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_lo.value["authorities"]),
                               np.asarray(r_di.value["authorities"]),
                               atol=1e-5)
    # the doubled-graph shards are derived state, partitioned once
    assert "hits/sharded" in di.cache
    shards = di.cache["hits/sharded"]
    di.hits()
    assert di.cache["hits/sharded"] is shards


def test_hits_through_platform_with_cache():
    g, _, _ = _graph()
    plat = GraphPlatform(g, n_data=4)
    q = GraphQuery.of("hits", max_iters=30)
    r = plat.query(q)
    assert r.engine in ("local", "distributed")
    assert set(r.value) == {"hubs", "authorities"}
    assert "plan" in r.meta
    r2 = plat.query(GraphQuery.of("hits", max_iters=30))
    assert r2.meta.get("cache") == "hit"
    assert plat.query(GraphQuery.of("hits", max_iters=31)).meta.get(
        "cache") is None


def test_hits_planner_spec():
    stats = P.GraphStats(1_000_000, 5_000_000, 5_000_000 * 12)
    spec = P.spec_for("hits", stats)
    assert spec.output_rows == 2 * stats.n_vertices
    assert spec.iterations == 30
    assert P.spec_for("hits", stats, max_iters=5).iterations == 5
    plan = P.choose_engine(stats, spec, 256)
    assert plan.engine in ("local", "distributed")
